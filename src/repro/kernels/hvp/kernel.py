"""Fused generalized-Hessian vector product kernel (CG inner-loop hot spot).

    Hv_l = 2 v_l + 2C X^T (act_l * (X v_l))

This runs once per CG iteration per Newton step — by far the most-executed
compute in DiSMEC training. Same (L/bl, N/bn) accumulation tiling as the
hinge kernel (see kernels/hinge/kernel.py for the VMEM budget): the (bl, bn)
masked intermediate act * (X v) lives only in VMEM.

`act` is the active-set payload the fused hinge kernel emitted at the
current Newton iterate (the margin-caching protocol, core/tron.py) — this
kernel performs ONE score-shaped contraction (X v) per call; the mask is
never re-derived.

`interpret=None` auto-selects per backend (compiled Mosaic on TPU, the
interpreter elsewhere — compat.default_pallas_interpret).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import resolve_interpret

DEFAULT_BL = 128
DEFAULT_BN = 128
MAX_FUSED_D = 8192


def _hvp_kernel(v_ref, x_ref, a_ref, o_ref, *, C: float):
    j = pl.program_id(1)
    V = v_ref[...].astype(jnp.float32)       # (bl, D)
    X = x_ref[...].astype(jnp.float32)       # (bn, D)
    A = a_ref[...].astype(jnp.float32)       # (bl, bn) active mask

    Xv = jax.lax.dot_general(V, X, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bl, bn)
    part = 2.0 * C * jax.lax.dot_general(A * Xv, X, (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = 2.0 * V

    o_ref[...] += part


def hvp_pallas(V: jax.Array, X: jax.Array, act: jax.Array, C: float,
               *, bl: int = DEFAULT_BL, bn: int = DEFAULT_BN,
               interpret: bool | None = None) -> jax.Array:
    """Raw pallas_call. Tile-aligned inputs only (L % bl == 0 and
    N % bn == 0; ops.py pads arbitrary shapes)."""
    L, D = V.shape
    N = X.shape[0]
    assert act.shape == (L, N), (act.shape, (L, N))
    if L % bl != 0 or N % bn != 0:
        raise ValueError(
            f"hvp_pallas needs tile-aligned inputs: got (L, N) = {(L, N)} "
            f"with tiles (bl, bn) = {(bl, bn)}; call "
            "repro.kernels.hvp.ops.hessian_vp for arbitrary shapes")
    grid = (L // bl, N // bn)
    return pl.pallas_call(
        partial(_hvp_kernel, C=C),
        grid=grid,
        in_specs=[pl.BlockSpec((bl, D), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
                  pl.BlockSpec((bl, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bl, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, D), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(V, X, act)
