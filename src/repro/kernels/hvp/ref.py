"""Pure-jnp oracle for the Hessian-vector-product kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hessian_vp(V: jax.Array, X: jax.Array, act: jax.Array,
               C: float) -> jax.Array:
    V = V.astype(jnp.float32)
    X = X.astype(jnp.float32)
    act = act.astype(jnp.float32)
    Xv = V @ X.T
    return 2.0 * V + 2.0 * C * ((act * Xv) @ X)
