"""Blocked top-k kernel — stage 1 of distributed prediction (paper §2.2.1).

The paper merges per-node block scores into a global top-k. On TPU the same
two-stage shape applies *within* a device: the L-dimensional score row never
materializes sorted; instead each (n, bL) score tile reduces to k candidates
(k iterations of masked max — k is 1/3/5 in XMC, so this beats any sort), and
the (n, n_blocks * k) candidate strip is merged by one small lax.top_k in
ops.py. HBM traffic drops from O(n L log L) sort traffic to O(n L) streaming.

VMEM: one (n, bL) tile + (n, k) outputs; n = 256, bL = 512 f32 is 512 KB.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BL = 512
NEG_INF = float(-3.0e38)


def _topk_kernel(s_ref, v_ref, i_ref, *, k: int, bL: int):
    j = pl.program_id(0)
    s = s_ref[...].astype(jnp.float32)                     # (n, bL)
    base = j * bL
    col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    for t in range(k):                                     # k static, tiny
        m = jnp.max(s, axis=1)
        am = jnp.argmax(s, axis=1).astype(jnp.int32)
        v_ref[:, t] = m
        i_ref[:, t] = am + base
        s = jnp.where(col == am[:, None], NEG_INF, s)


def blocked_topk_pallas(scores: jax.Array, k: int, *, bL: int = DEFAULT_BL,
                        interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """scores (n, L) with L % bL == 0 -> per-block candidates
    (vals, idx) each (n, (L/bL) * k), idx in global label coordinates."""
    n, L = scores.shape
    assert L % bL == 0
    nb = L // bL
    return pl.pallas_call(
        partial(_topk_kernel, k=k, bL=bL),
        grid=(nb,),
        in_specs=[pl.BlockSpec((n, bL), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((n, k), lambda j: (0, j)),
                   pl.BlockSpec((n, k), lambda j: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((n, nb * k), jnp.float32),
                   jax.ShapeDtypeStruct((n, nb * k), jnp.int32)],
        interpret=interpret,
    )(scores)
