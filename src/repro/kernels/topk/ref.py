"""Pure-jnp oracle for blocked top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    return jax.lax.top_k(scores.astype(jnp.float32), k)
