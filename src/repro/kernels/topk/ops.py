"""Public wrapper: two-stage top-k (Pallas block reduce + small merge)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.topk.kernel import NEG_INF, blocked_topk_pallas


@partial(jax.jit, static_argnames=("k", "bL", "interpret"))
def topk(scores: jax.Array, k: int, *, bL: int = 512,
         interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Top-k values and global indices per row of scores (n, L).

    Pads L with -inf to a block multiple, reduces each block to k candidates
    in VMEM, merges the candidate strip with one small lax.top_k.
    """
    n, L = scores.shape
    bL = min(bL, max(k, 128))  if L < bL else bL
    p = (-L) % bL
    if p:
        scores = jnp.pad(scores, ((0, 0), (0, p)), constant_values=NEG_INF)
    vals, idx = blocked_topk_pallas(scores, k, bL=bL, interpret=interpret)
    top_vals, pos = jax.lax.top_k(vals, k)
    top_idx = jnp.take_along_axis(idx, pos, axis=1)
    return top_vals, top_idx
