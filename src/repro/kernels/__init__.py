"""Pallas TPU kernels for DiSMEC's compute hot-spots.

Each kernel directory contains:
  kernel.py — pl.pallas_call body + BlockSpec tiling (TPU target)
  ops.py    — jit'd public wrapper with shape checks / fallbacks
  ref.py    — pure-jnp oracle the tests assert against

Kernels (DESIGN.md §3):
  hinge       fused squared-hinge objective + gradient + active mask
              (TRON outer loop; the mask output feeds the margin-caching
              solver protocol, core/tron.py)
  hvp         fused generalized-Hessian vector product consuming the
              cached mask (CG inner loop)
  bsr_predict block-sparse W x predict — skips Delta-pruned zero blocks
  topk        blocked two-stage top-k for distributed prediction

All kernels are validated on CPU with interpret=True; on TPU the same
pallas_call lowers to Mosaic. The training kernels (hinge/hvp) take
`interpret=None` and auto-select per backend (compiled Mosaic on TPU,
interpreter elsewhere — compat.default_pallas_interpret). VMEM budgets
are documented per kernel.
"""
