"""Pallas banded sliding-window attention — the backbone hot spot that the
SSPerf hillclimb moved from O(T^2) masking to O(T * (w + qc)) band slicing
(EXPERIMENTS.md, hymba iteration 2), here as an explicit TPU kernel.

Tiling
------
grid = (B * KV, nq): one step per (batch x kv-head group, query block).
The query block (G, qc, hd) lives in VMEM via BlockSpec; K/V stay UNBLOCKED
(memory_space ANY -> HBM on TPU) and the kernel pl.loads exactly the
[band_start, band_start + span) rows it attends to — the DMA the XLA-level
implementation relies on the compiler to find, made explicit.

Band geometry: span = window + qc rounded up to a lane multiple; the start
is clamped so the slice never leaves [0, Tk]. Causal + window masking is
applied from absolute positions inside the kernel.

VMEM budget per step (f32): q (G, qc, hd) + band K/V 2*(span, hd) + scores
(G*qc, span). hymba prefill (G=5, qc=256, hd=64, w=1024, span=1280):
0.3 MB + 0.7 MB + 6.5 MB ~= 7.5 MB < 16 MB v5e VMEM. ops.py asserts this.

MXU: scores (G*qc, hd) x (hd, span) and (G*qc, span) x (span, hd) — both
lane-aligned for hd, span multiples of 128.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_QC = 256
NEG_INF = float(-3.0e38)


def _banded_kernel(q_ref, k_ref, v_ref, o_ref, *, window: int, span: int,
                   qc: int, Tk: int, scale: float):
    """One (batch*kv-head, q-block) step."""
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                   # (G, qc, hd)
    G, _, hd = q.shape

    q_end = (qi + 1) * qc
    start = jnp.clip(q_end - span, 0, Tk - span)
    # The leading batch index must be a traced scalar, not a Python int:
    # jax 0.4.x's interpret-mode discharge rule assumes every non-Slice
    # index has a .shape.
    zero = jnp.int32(0)
    k = pl.load(k_ref, (zero, pl.ds(start, span), slice(None))
                ).astype(jnp.float32)                  # (span, hd)
    v = pl.load(v_ref, (zero, pl.ds(start, span), slice(None))
                ).astype(jnp.float32)

    qf = q.reshape(G * qc, hd)
    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # Rows are (g, q) flattened g-major; the position is the q component.
    row_q = (jax.lax.broadcasted_iota(jnp.int32, (G * qc, span), 0) % qc) \
        + qi * qc
    col_k = start + jax.lax.broadcasted_iota(jnp.int32, (G * qc, span), 1)
    mask = (col_k <= row_q) & (col_k > row_q - window)
    s = jnp.where(mask, s, NEG_INF)

    p = jax.nn.softmax(s, axis=-1)
    out = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0] = out.reshape(G, qc, hd).astype(o_ref.dtype)


def banded_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            *, window: int, qc: int = DEFAULT_QC,
                            interpret: bool = True) -> jax.Array:
    """q (BKV, G, Tq, hd), k/v (BKV, Tk, hd) -> (BKV, G, Tq, hd).

    Requires Tq % qc == 0 and span <= Tk (ops.py pads/validates).
    """
    BKV, G, Tq, hd = q.shape
    Tk = k.shape[1]
    assert Tq % qc == 0
    nq = Tq // qc
    # Lane-align the band span.
    span = min(Tk, ((window + qc + 127) // 128) * 128)
    scale = 1.0 / math.sqrt(hd)

    return pl.pallas_call(
        partial(_banded_kernel, window=window, span=span, qc=qc, Tk=Tk,
                scale=scale),
        grid=(BKV, nq),
        in_specs=[
            pl.BlockSpec((1, G, qc, hd), lambda b, i: (b, 0, i, 0)),
            pl.BlockSpec((1, Tk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Tk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, qc, hd), lambda b, i: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, G, Tq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
