"""Public wrapper for the banded attention kernel: GQA layout, padding,
VMEM budget enforcement, fallback."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.banded_attn import ref
from repro.kernels.banded_attn.kernel import (DEFAULT_QC,
                                              banded_attention_pallas)

VMEM_BUDGET = 14 * 2 ** 20         # leave headroom under 16 MB v5e VMEM


def _vmem_bytes(G: int, qc: int, hd: int, span: int) -> int:
    q = G * qc * hd * 4
    kv = 2 * span * hd * 4
    scores = G * qc * span * 4
    out = G * qc * hd * 4
    return q + kv + scores + out


@partial(jax.jit, static_argnames=("window", "qc", "interpret"))
def banded_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     *, window: int, qc: int = DEFAULT_QC,
                     interpret: bool = True) -> jax.Array:
    """Sliding-window attention, (B, Tq, H, hd) x (B, Tk, KV, hd) GQA layout
    (same convention as models/layers.py) -> (B, Tq, H * hd).

    Routes through the Pallas kernel when the band working set fits VMEM,
    else falls back to the jnp oracle (which the XLA-level
    layers.banded_attention already covers in production paths).
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = min(qc, Tq)
    while Tq % qc:
        qc //= 2
    span = min(Tk, ((window + qc + 127) // 128) * 128)

    # (B, Tq, H, hd) -> (B*KV, G, Tq, hd); k/v -> (B*KV, Tk, hd)
    q4 = q.reshape(B, Tq, KV, G, hd).transpose(0, 2, 3, 1, 4) \
          .reshape(B * KV, G, Tq, hd)
    k3 = k.transpose(0, 2, 1, 3).reshape(B * KV, Tk, hd)
    v3 = v.transpose(0, 2, 1, 3).reshape(B * KV, Tk, hd)

    if _vmem_bytes(G, qc, hd, span) <= VMEM_BUDGET and span <= Tk:
        out = banded_attention_pallas(q4, k3, v3, window=window, qc=qc,
                                      interpret=interpret)
    else:
        out = ref.banded_attention(q4, k3, v3, window=window)

    # (B*KV, G, Tq, hd) -> (B, Tq, H*hd)
    out = out.reshape(B, KV, G, Tq, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Tq, H * hd)
