from repro.kernels.banded_attn.ops import banded_attention  # noqa: F401
