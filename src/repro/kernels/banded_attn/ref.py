"""Pure-jnp oracle for the banded attention kernel: dense scores + mask."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def banded_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     *, window: int) -> jax.Array:
    """q (BKV, G, Tq, hd), k/v (BKV, Tk, hd) -> (BKV, G, Tq, hd).

    Dense causal sliding-window attention (materializes (Tq, Tk) scores —
    oracle only)."""
    BKV, G, Tq, hd = q.shape
    Tk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bgqh,bkh->bgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qi = jnp.arange(Tq)[:, None]
    ki = jnp.arange(Tk)[None, :]
    mask = (ki <= qi) & (ki > qi - window)
    s = jnp.where(mask[None, None], s, -3.0e38)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgqk,bkh->bgqh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
