"""Public wrapper for the fused hinge kernel: padding, bounds, fallback."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.hinge import ref
from repro.kernels.hinge.kernel import (MAX_FUSED_D, hinge_obj_grad_pallas)


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0):
    n = x.shape[axis]
    p = (-n) % mult
    if p == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, p)
    return jnp.pad(x, pad, constant_values=value)


@partial(jax.jit, static_argnames=("C", "bl", "bn", "interpret"))
def objective_grad_act(W: jax.Array, X: jax.Array, S: jax.Array, C: float,
                       *, bl: int = 128, bn: int = 128,
                       interpret: bool | None = None,
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused (objective, gradient, active mask) for all labels; pads L and N
    to tile multiples. Padded instances get sign -1 and x = 0 => margin
    z = 1 - 0 = 1 > 0: active regardless of sign, so each pad row adds a
    constant C to every label's objective — subtracted back analytically —
    while its gradient contribution is exactly 0 (r x = 0). Padded label
    rows (W = 0, S = -1) and padded mask rows/columns are sliced away: the
    returned act is the true (L, N) mask, directly consumable by the HVP
    kernel (whose wrapper re-pads with zeros — a zero-mask instance
    contributes nothing).
    """
    L, D = W.shape
    N = X.shape[0]
    if D > MAX_FUSED_D:
        return ref.objective_grad_act(W, X, S, C)

    Wp = _pad_to(W, 0, bl)
    Xp = _pad_to(X, 0, bn)
    Sp = _pad_to(_pad_to(S, 0, bl, -1.0), 1, bn, -1.0)
    n_pad_inst = Xp.shape[0] - N

    f, g, act = hinge_obj_grad_pallas(Wp, Xp, Sp, C, bl=bl, bn=bn,
                                      interpret=interpret)
    # Each padded instance (x = 0, s = -1) is active with z = 1 for every
    # label: remove its constant C contribution from the objective.
    f = f[:L] - C * n_pad_inst
    return f, g[:L], act[:L, :N]


@partial(jax.jit, static_argnames=("C", "bl", "bn", "interpret"))
def objective_and_grad(W: jax.Array, X: jax.Array, S: jax.Array, C: float,
                       *, bl: int = 128, bn: int = 128,
                       interpret: bool | None = None,
                       ) -> tuple[jax.Array, jax.Array]:
    """(f, grad) only — see `objective_grad_act` for the solver-facing form
    that also emits the active mask from the same score pass."""
    f, g, _ = objective_grad_act(W, X, S, C, bl=bl, bn=bn,
                                 interpret=interpret)
    return f, g
