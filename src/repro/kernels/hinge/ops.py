"""Public wrapper for the fused hinge kernel: padding, bounds, fallback."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.hinge import ref
from repro.kernels.hinge.kernel import (MAX_FUSED_D, hinge_obj_grad_pallas)


def _pad_to(x: jax.Array, axis: int, mult: int, value: float = 0.0):
    n = x.shape[axis]
    p = (-n) % mult
    if p == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, p)
    return jnp.pad(x, pad, constant_values=value)


@partial(jax.jit, static_argnames=("C", "bl", "bn", "interpret"))
def objective_and_grad(W: jax.Array, X: jax.Array, S: jax.Array, C: float,
                       *, bl: int = 128, bn: int = 128,
                       interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused (objective, gradient) for all labels; pads L and N to tile
    multiples. Padded instances get sign -1 and x = 0 => margin = 1 - 0 > 0
    is ACTIVE but contributes z=1, f += C per pad row — so we pad S with a
    sign of -1 *and* scores 0 give z = 1: wrong. Instead pad S with +1 and
    x = 0: z = 1 - 0 = 1 active again. Zero-rows always contribute C to f
    regardless of sign, so we subtract the analytic pad contribution, and
    their gradient contribution is exactly 0 (r x = 0). Padded labels (rows
    of W = 0, S = -1) are sliced away.
    """
    L, D = W.shape
    N = X.shape[0]
    if D > MAX_FUSED_D:
        return ref.objective_and_grad(W, X, S, C)

    Wp = _pad_to(W, 0, bl)
    Xp = _pad_to(X, 0, bn)
    Sp = _pad_to(_pad_to(S, 0, bl, -1.0), 1, bn, -1.0)
    n_pad_inst = Xp.shape[0] - N

    f, g = hinge_obj_grad_pallas(Wp, Xp, Sp, C, bl=bl, bn=bn,
                                 interpret=interpret)
    # Each padded instance (x = 0, s = -1) is active with z = 1 for every
    # label: remove its constant C contribution from the objective.
    f = f[:L] - C * n_pad_inst
    return f, g[:L]
