"""Pure-jnp oracle for the fused squared-hinge objective+gradient kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def objective_grad_act(W: jax.Array, X: jax.Array, S: jax.Array,
                       C: float) -> tuple[jax.Array, jax.Array, jax.Array]:
    W = W.astype(jnp.float32)
    X = X.astype(jnp.float32)
    S = S.astype(jnp.float32)
    scores = W @ X.T
    z = 1.0 - S * scores
    act = (z > 0.0).astype(jnp.float32)
    r = act * (scores - S)
    f = jnp.sum(W * W, axis=-1) + C * jnp.sum(act * z * z, axis=-1)
    grad = 2.0 * W + 2.0 * C * (r @ X)
    return f, grad, act


def objective_and_grad(W: jax.Array, X: jax.Array, S: jax.Array,
                       C: float) -> tuple[jax.Array, jax.Array]:
    f, grad, _ = objective_grad_act(W, X, S, C)
    return f, grad
