"""Fused squared-hinge objective + gradient + active-mask kernel (TRON
outer-loop hot spot).

Computes, for a shard of labels at once (paper layer-2 parallelism):

    f_l    = ||w_l||^2 + C sum_i max(0, 1 - s_li <w_l, x_i>)^2
    grad_l = 2 w_l + 2C sum_i act_li (<w_l, x_i> - s_li) x_i
    act_li = 1[1 - s_li <w_l, x_i> > 0]        (the label's active set I_l)

The third output is the margin-caching solver protocol's `act_aux`
(core/tron.py): the mask is emitted tile-by-tile from the SAME score
contraction that feeds f/grad, so the TRON/CG loop never runs a separate
(L, D) x (D, N) matmul just to rebuild the active set — the HVP kernel
(kernels/hvp) consumes this mask directly.

Tiling
------
grid = (L/bl, N/bn); j (instances) is the innermost, sequential axis so the
(bl,)-objective and (bl, D)-gradient output blocks are *revisited* and
accumulated in VMEM across the N sweep — the margin nonlinearity is applied
tile-by-tile with zero HBM round-trips for the (L, N) score matrix. The
(bl, bn) act tile is written exactly once, at its own (i, j) grid step.

VMEM budget (f32, bl = bn = 128, D <= 8192):
    W tile 4 MB + X tile 4 MB + grad tile 4 MB + S/score/act tiles 192 KB
    ~= 12.3 MB < 16 MB v5e VMEM.  ops.py enforces the D bound and falls
back to the decomposed jnp path for larger D.

MXU notes: both contractions are (128 x D) x (D x 128) and (128 x 128) x
(128 x D) — lane/sublane aligned; f32 accumulation via
preferred_element_type regardless of input dtype.

`interpret=None` auto-selects per backend (compiled Mosaic on TPU, the
interpreter elsewhere — compat.default_pallas_interpret).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import resolve_interpret

DEFAULT_BL = 128      # label-tile rows
DEFAULT_BN = 128      # instance-tile rows
MAX_FUSED_D = 8192    # full-D blocks must fit VMEM (see module docstring)


def _hinge_kernel(w_ref, x_ref, s_ref, f_ref, g_ref, a_ref, *, C: float):
    """One (label-tile i, instance-tile j) grid step."""
    j = pl.program_id(1)
    W = w_ref[...].astype(jnp.float32)       # (bl, D)
    X = x_ref[...].astype(jnp.float32)       # (bn, D)
    S = s_ref[...].astype(jnp.float32)       # (bl, bn)

    scores = jax.lax.dot_general(W, X, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    z = 1.0 - S * scores
    act = (z > 0.0).astype(jnp.float32)
    r = act * (scores - S)                   # = -act * S * z

    f_part = C * jnp.sum(act * z * z, axis=1)
    g_part = 2.0 * C * jax.lax.dot_general(r, X, (((1,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():                             # regularizer terms, once per row-tile
        f_ref[...] = jnp.sum(W * W, axis=1)
        g_ref[...] = 2.0 * W

    f_ref[...] += f_part
    g_ref[...] += g_part
    a_ref[...] = act                         # (i, j) tile, written once


def hinge_obj_grad_pallas(W: jax.Array, X: jax.Array, S: jax.Array, C: float,
                          *, bl: int = DEFAULT_BL, bn: int = DEFAULT_BN,
                          interpret: bool | None = None):
    """Raw pallas_call -> (f, grad, act). Tile-aligned inputs only (L % bl
    == 0 and N % bn == 0; ops.py pads arbitrary shapes)."""
    L, D = W.shape
    N = X.shape[0]
    assert S.shape == (L, N), (S.shape, (L, N))
    if L % bl != 0 or N % bn != 0:
        raise ValueError(
            f"hinge_obj_grad_pallas needs tile-aligned inputs: got "
            f"(L, N) = {(L, N)} with tiles (bl, bn) = {(bl, bn)}; call "
            "repro.kernels.hinge.ops.objective_grad_act for arbitrary shapes")
    grid = (L // bl, N // bn)
    return pl.pallas_call(
        partial(_hinge_kernel, C=C),
        grid=grid,
        in_specs=[pl.BlockSpec((bl, D), lambda i, j: (i, 0)),
                  pl.BlockSpec((bn, D), lambda i, j: (j, 0)),
                  pl.BlockSpec((bl, bn), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bl,), lambda i, j: (i,)),
                   pl.BlockSpec((bl, D), lambda i, j: (i, 0)),
                   pl.BlockSpec((bl, bn), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((L,), jnp.float32),
                   jax.ShapeDtypeStruct((L, D), jnp.float32),
                   jax.ShapeDtypeStruct((L, N), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(W, X, S)
