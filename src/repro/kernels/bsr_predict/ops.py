"""Public wrapper: BSR prediction over a pruned DiSMEC model.

`bsr_predict` yields the dense (n, Lp) score matrix; `bsr_predict_topk`
fuses it with the blocked Pallas top-k (kernels/topk) into the serving
entry point used by `repro.serve.xmc.BsrBackend` — scores never leave the
padded block coordinate system before being reduced to k candidates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import BlockSparseModel
from repro.kernels.bsr_predict.kernel import bsr_predict_pallas
from repro.kernels.topk.kernel import NEG_INF


def bsr_predict(x: jax.Array, model: BlockSparseModel,
                *, interpret: bool = True) -> jax.Array:
    """Scores (n, L) for a batch against a block-sparse model.

    Pads x's feature dim to the padded model shape and zeroes out label
    row-blocks that have no surviving blocks (never visited by the kernel).
    """
    Lp, Dp = model.shape
    bl, bd = model.block_shape
    n, D = x.shape
    if D < Dp:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
    out = bsr_predict_pallas(x, model.blocks, model.block_rows,
                             model.block_cols, Lp // bl, interpret=interpret)
    # Mask empty row-blocks (undefined memory in the kernel output -- may be
    # NaN in interpret mode, so select rather than multiply).
    counts = model.row_ptr[1:] - model.row_ptr[:-1]          # (Lp/bl,)
    row_mask = jnp.repeat(counts > 0, bl)
    return jnp.where(row_mask[None, :], out, 0.0)


def bsr_predict_topk(x: jax.Array, model: BlockSparseModel, k: int,
                     *, n_labels: int | None = None,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused predict -> top-k: (vals, idx) each (n, k), idx in true label ids.

    Padding label rows (id >= n_labels) are masked to -inf between the two
    kernels so a block-padded model never serves phantom labels. Fully
    pruned real labels keep their exact-zero score, matching the dense path.
    """
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    scores = bsr_predict(x, model, interpret=interpret)
    Lp = scores.shape[1]
    if n_labels is not None and n_labels < Lp:
        ids = jnp.arange(Lp)
        scores = jnp.where(ids[None, :] < n_labels, scores, NEG_INF)
    return topk_ops.topk(scores, k, interpret=interpret)


def model_flops(model: BlockSparseModel, n: int) -> int:
    """FLOPs actually executed: 2 * n * bl * bd per surviving block —
    the block-density speedup the kernel realizes over dense predict."""
    bl, bd = model.block_shape
    return 2 * n * bl * bd * model.n_blocks


def dense_flops(model: BlockSparseModel, n: int) -> int:
    Lp, Dp = model.shape
    return 2 * n * Lp * Dp
