"""Public wrapper: BSR prediction over a pruned DiSMEC model."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import BlockSparseModel
from repro.kernels.bsr_predict.kernel import bsr_predict_pallas


def bsr_predict(x: jax.Array, model: BlockSparseModel,
                *, interpret: bool = True) -> jax.Array:
    """Scores (n, L) for a batch against a block-sparse model.

    Pads x's feature dim to the padded model shape and zeroes out label
    row-blocks that have no surviving blocks (never visited by the kernel).
    """
    Lp, Dp = model.shape
    bl, bd = model.block_shape
    n, D = x.shape
    if D < Dp:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
    out = bsr_predict_pallas(x, model.blocks, model.block_rows,
                             model.block_cols, Lp // bl, interpret=interpret)
    # Mask empty row-blocks (undefined memory in the kernel output -- may be
    # NaN in interpret mode, so select rather than multiply).
    counts = model.row_ptr[1:] - model.row_ptr[:-1]          # (Lp/bl,)
    row_mask = jnp.repeat(counts > 0, bl)
    return jnp.where(row_mask[None, :], out, 0.0)


def model_flops(model: BlockSparseModel, n: int) -> int:
    """FLOPs actually executed: 2 * n * bl * bd per surviving block —
    the block-density speedup the kernel realizes over dense predict."""
    bl, bd = model.block_shape
    return 2 * n * bl * bd * model.n_blocks


def dense_flops(model: BlockSparseModel, n: int) -> int:
    Lp, Dp = model.shape
    return 2 * n * Lp * Dp
