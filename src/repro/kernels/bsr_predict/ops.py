"""Public wrapper: BSR prediction over a pruned DiSMEC model.

`bsr_predict` yields the dense (n, Lp) score matrix; `bsr_predict_topk`
fuses it with the blocked Pallas top-k (kernels/topk) into the serving
entry point used by `repro.serve.xmc.BsrBackend` — scores never leave the
padded block coordinate system before being reduced to k candidates.

`bsr_predict_gather` / `bsr_predict_gather_topk` are the shortlist-gated
variants (serve/shortlist.py): given a per-batch list of selected row
blocks they score ONLY those blocks' packed tiles, so per-query compute
scales with B * block_size instead of L. With the selection covering all
row blocks (sorted) they reproduce the exhaustive path bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import BlockSparseModel
from repro.kernels.bsr_predict.kernel import (bsr_predict_gather_pallas,
                                              bsr_predict_pallas)
from repro.kernels.topk.kernel import NEG_INF


def bsr_predict(x: jax.Array, model: BlockSparseModel,
                *, interpret: bool = True) -> jax.Array:
    """Scores (n, L) for a batch against a block-sparse model.

    Pads x's feature dim to the padded model shape and zeroes out label
    row-blocks that have no surviving blocks (never visited by the kernel).
    """
    Lp, Dp = model.shape
    bl, bd = model.block_shape
    n, D = x.shape
    if D < Dp:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
    out = bsr_predict_pallas(x, model.blocks, model.block_rows,
                             model.block_cols, Lp // bl, interpret=interpret)
    # Mask empty row-blocks (undefined memory in the kernel output -- may be
    # NaN in interpret mode, so select rather than multiply).
    counts = model.row_ptr[1:] - model.row_ptr[:-1]          # (Lp/bl,)
    row_mask = jnp.repeat(counts > 0, bl)
    return jnp.where(row_mask[None, :], out, 0.0)


def bsr_predict_topk(x: jax.Array, model: BlockSparseModel, k: int,
                     *, n_labels: int | None = None,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused predict -> top-k: (vals, idx) each (n, k), idx in true label ids.

    Padding label rows (id >= n_labels) are masked to -inf between the two
    kernels so a block-padded model never serves phantom labels. Fully
    pruned real labels keep their exact-zero score, matching the dense path.
    """
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    scores = bsr_predict(x, model, interpret=interpret)
    Lp = scores.shape[1]
    if n_labels is not None and n_labels < Lp:
        ids = jnp.arange(Lp)
        scores = jnp.where(ids[None, :] < n_labels, scores, NEG_INF)
    return topk_ops.topk(scores, k, interpret=interpret)


def max_blocks_per_row(model: BlockSparseModel) -> int:
    """Static bound on packed blocks per row block (>= 1) — the inner grid
    extent of the gathered-block kernel."""
    ptr = np.asarray(model.row_ptr)
    return max(1, int(np.max(ptr[1:] - ptr[:-1])))


def bsr_predict_gather(x: jax.Array, model: BlockSparseModel,
                       sel: jax.Array, *,
                       max_per_row: int | None = None,
                       interpret: bool = True) -> jax.Array:
    """Scores for ONLY the row blocks listed in `sel` (B,) int32.

    Returns (n, B * bl): columns [i*bl, (i+1)*bl) are row block sel[i]'s
    label scores. Pads x's feature dim like `bsr_predict`; a selected row
    block with no surviving blocks comes back exact-zero (the kernel
    zero-initializes every selected output tile), so pruned labels keep
    the dense path's score convention without any extra masking.
    """
    Lp, Dp = model.shape
    n, D = x.shape
    if D < Dp:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
    if max_per_row is None:
        max_per_row = max_blocks_per_row(model)
    return bsr_predict_gather_pallas(
        x, model.blocks, model.block_cols, model.row_ptr,
        jnp.asarray(sel, jnp.int32), max_per_row, interpret=interpret)


def bsr_predict_gather_topk(x: jax.Array, model: BlockSparseModel,
                            sel: jax.Array, k: int, *,
                            n_labels: int | None = None,
                            max_per_row: int | None = None,
                            interpret: bool = True,
                            ) -> tuple[jax.Array, jax.Array]:
    """Fused gathered predict -> top-k over the shortlisted labels only.

    (vals, idx) each (n, k); idx in TRUE label ids (candidates translated
    back through `sel`). Padding labels (global id >= n_labels) are masked
    to -inf between the kernels. With `sel` sorted ascending and covering
    every row block this reproduces `bsr_predict_topk` exactly, tie order
    included — the B-covers-all equivalence the shortlist backend tests
    gate on.
    """
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    bl = model.block_shape[0]
    sel = jnp.asarray(sel, jnp.int32)
    scores = bsr_predict_gather(x, model, sel, max_per_row=max_per_row,
                                interpret=interpret)
    # Candidate column -> true label id, used both to mask block padding
    # and to translate the merged top-k back to label coordinates.
    label_ids = (sel[:, None] * bl + jnp.arange(bl)[None, :]).reshape(-1)
    if n_labels is not None:
        scores = jnp.where(label_ids[None, :] < n_labels, scores, NEG_INF)
    vals, idx = topk_ops.topk(scores, k, interpret=interpret)
    return vals, jnp.take(label_ids, idx)


def gather_flops(model: BlockSparseModel, n: int, sel: np.ndarray) -> int:
    """FLOPs the gathered fine stage actually executes for one batch:
    2 * n * bl * bd per surviving block of the selected row blocks."""
    bl, bd = model.block_shape
    ptr = np.asarray(model.row_ptr)
    sel = np.asarray(sel)
    n_sel_blocks = int((ptr[sel + 1] - ptr[sel]).sum())
    return 2 * n * bl * bd * n_sel_blocks


def model_flops(model: BlockSparseModel, n: int) -> int:
    """FLOPs actually executed: 2 * n * bl * bd per surviving block —
    the block-density speedup the kernel realizes over dense predict."""
    bl, bd = model.block_shape
    return 2 * n * bl * bd * model.n_blocks


def dense_flops(model: BlockSparseModel, n: int) -> int:
    Lp, Dp = model.shape
    return 2 * n * Lp * Dp
