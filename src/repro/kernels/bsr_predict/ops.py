"""Public wrapper: BSR prediction over a pruned DiSMEC model.

`bsr_predict` yields the dense (n, Lp) score matrix; `bsr_predict_topk`
fuses it with the blocked Pallas top-k (kernels/topk) into the serving
entry point used by `repro.serve.xmc.BsrBackend` — scores never leave the
padded block coordinate system before being reduced to k candidates.

`bsr_predict_gather` / `bsr_predict_gather_topk` are the shortlist-gated
variants (serve/shortlist.py): given a per-batch list of selected row
blocks they score ONLY those blocks' packed tiles, so per-query compute
scales with B * block_size instead of L. With the selection covering all
row blocks (sorted) they reproduce the exhaustive path bit-for-bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import BlockSparseModel, Int8BlockSparseModel
from repro.kernels.bsr_predict.kernel import (bsr_predict_gather_int8_pallas,
                                              bsr_predict_gather_pallas,
                                              bsr_predict_gather_pq_int8_pallas,
                                              bsr_predict_gather_pq_pallas,
                                              bsr_predict_int8_pallas,
                                              bsr_predict_pallas)
from repro.kernels.topk.kernel import NEG_INF


def _pad_features(x: jax.Array, model) -> jax.Array:
    """Pad x (n, D) to the model's padded feature width Dp.

    D > Dp is a hard error with both dims named: the old D < Dp branch
    silently fell through on oversized requests, which then shape-erred
    deep inside the kernel's BlockSpec machinery (or mis-scored under jit
    where the trace point is far from the caller).
    """
    Dp = model.shape[1]
    D = x.shape[1]
    if D > Dp:
        raise ValueError(
            f"request feature dim {D} exceeds the model's padded feature "
            f"dim {Dp} (true feature dim {model.n_features}); bsr_predict "
            "cannot score features the model never had — slice the request "
            "or rebuild the model with the wider feature space")
    if D < Dp:
        x = jnp.pad(x, ((0, 0), (0, Dp - D)))
    return x


def _mask_empty_row_blocks(out: jax.Array, model) -> jax.Array:
    # Mask empty row-blocks (undefined memory in the kernel output -- may be
    # NaN in interpret mode, so select rather than multiply).
    bl = model.block_shape[0]
    counts = model.row_ptr[1:] - model.row_ptr[:-1]          # (Lp/bl,)
    row_mask = jnp.repeat(counts > 0, bl)
    return jnp.where(row_mask[None, :], out, 0.0)


def bsr_predict(x: jax.Array, model: BlockSparseModel,
                *, interpret: bool = True) -> jax.Array:
    """Scores (n, L) for a batch against a block-sparse model.

    Pads x's feature dim to the padded model shape (raising when the
    request is WIDER than the model) and zeroes out label row-blocks that
    have no surviving blocks (never visited by the kernel).
    """
    Lp, Dp = model.shape
    bl, bd = model.block_shape
    x = _pad_features(x, model)
    out = bsr_predict_pallas(x, model.blocks, model.block_rows,
                             model.block_cols, Lp // bl, interpret=interpret)
    return _mask_empty_row_blocks(out, model)


def bsr_predict_int8(x: jax.Array, model: Int8BlockSparseModel,
                     *, interpret: bool = True) -> jax.Array:
    """Scores (n, L) against the int8 per-block-scaled artifact — same
    pad/mask conventions as `bsr_predict`, ~0.25x the model HBM traffic.
    Scores match the fp32 path within the per-block quantization bound
    (|w - scale*q| <= scale/2 elementwise)."""
    Lp, Dp = model.shape
    bl, bd = model.block_shape
    x = _pad_features(x, model)
    out = bsr_predict_int8_pallas(x, model.blocks, model.scales,
                                  model.block_rows, model.block_cols,
                                  Lp // bl, interpret=interpret)
    return _mask_empty_row_blocks(out, model)


def bsr_predict_topk(x: jax.Array, model: BlockSparseModel, k: int,
                     *, n_labels: int | None = None,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused predict -> top-k: (vals, idx) each (n, k), idx in true label ids.

    Padding label rows (id >= n_labels) are masked to -inf between the two
    kernels so a block-padded model never serves phantom labels. Fully
    pruned real labels keep their exact-zero score, matching the dense path.
    """
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    scores = bsr_predict(x, model, interpret=interpret)
    Lp = scores.shape[1]
    if n_labels is not None and n_labels < Lp:
        ids = jnp.arange(Lp)
        scores = jnp.where(ids[None, :] < n_labels, scores, NEG_INF)
    return topk_ops.topk(scores, k, interpret=interpret)


def bsr_predict_int8_topk(x: jax.Array, model: Int8BlockSparseModel, k: int,
                          *, n_labels: int | None = None,
                          interpret: bool = True,
                          ) -> tuple[jax.Array, jax.Array]:
    """Fused int8 predict -> top-k: (vals, idx) each (n, k), idx in true
    label ids — the `"int8"` backend's serving entry point. Padding labels
    are masked to -inf between the kernels and fully pruned real labels
    keep their exact-zero score (an all-zero block quantizes to scale 0),
    matching the fp32 conventions."""
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    scores = bsr_predict_int8(x, model, interpret=interpret)
    Lp = scores.shape[1]
    if n_labels is not None and n_labels < Lp:
        ids = jnp.arange(Lp)
        scores = jnp.where(ids[None, :] < n_labels, scores, NEG_INF)
    return topk_ops.topk(scores, k, interpret=interpret)


def max_blocks_per_row(model: BlockSparseModel) -> int:
    """Static bound on packed blocks per row block (>= 1) — the inner grid
    extent of the gathered-block kernel."""
    ptr = np.asarray(model.row_ptr)
    return max(1, int(np.max(ptr[1:] - ptr[:-1])))


def bsr_predict_gather(x: jax.Array, model: BlockSparseModel,
                       sel: jax.Array, *,
                       max_per_row: int | None = None,
                       interpret: bool = True) -> jax.Array:
    """Scores for ONLY the row blocks listed in `sel` (B,) int32.

    Returns (n, B * bl): columns [i*bl, (i+1)*bl) are row block sel[i]'s
    label scores. Pads x's feature dim like `bsr_predict`; a selected row
    block with no surviving blocks comes back exact-zero (the kernel
    zero-initializes every selected output tile), so pruned labels keep
    the dense path's score convention without any extra masking.
    """
    x = _pad_features(x, model)
    if max_per_row is None:
        max_per_row = max_blocks_per_row(model)
    return bsr_predict_gather_pallas(
        x, model.blocks, model.block_cols, model.row_ptr,
        jnp.asarray(sel, jnp.int32), max_per_row, interpret=interpret)


def bsr_predict_gather_int8(x: jax.Array, model: Int8BlockSparseModel,
                            sel: jax.Array, *,
                            max_per_row: int | None = None,
                            interpret: bool = True) -> jax.Array:
    """Int8 scores for ONLY the row blocks listed in `sel` (B,) int32 —
    the shortlist fine stage over the quantized artifact. Same contract
    as `bsr_predict_gather` (exact-zero empty blocks included: their
    packed sentinel quantizes to zeros)."""
    x = _pad_features(x, model)
    if max_per_row is None:
        max_per_row = max_blocks_per_row(model)
    return bsr_predict_gather_int8_pallas(
        x, model.blocks, model.scales, model.block_cols, model.row_ptr,
        jnp.asarray(sel, jnp.int32), max_per_row, interpret=interpret)


def bsr_predict_gather_topk(x: jax.Array, model: BlockSparseModel,
                            sel: jax.Array, k: int, *,
                            n_labels: int | None = None,
                            max_per_row: int | None = None,
                            interpret: bool = True,
                            ) -> tuple[jax.Array, jax.Array]:
    """Fused gathered predict -> top-k over the shortlisted labels only.

    (vals, idx) each (n, k); idx in TRUE label ids (candidates translated
    back through `sel`). Padding labels (global id >= n_labels) are masked
    to -inf between the kernels. With `sel` sorted ascending and covering
    every row block this reproduces `bsr_predict_topk` exactly, tie order
    included — the B-covers-all equivalence the shortlist backend tests
    gate on.
    """
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    bl = model.block_shape[0]
    sel = jnp.asarray(sel, jnp.int32)
    scores = bsr_predict_gather(x, model, sel, max_per_row=max_per_row,
                                interpret=interpret)
    # Candidate column -> true label id, used both to mask block padding
    # and to translate the merged top-k back to label coordinates.
    label_ids = (sel[:, None] * bl + jnp.arange(bl)[None, :]).reshape(-1)
    if n_labels is not None:
        scores = jnp.where(label_ids[None, :] < n_labels, scores, NEG_INF)
    vals, idx = topk_ops.topk(scores, k, interpret=interpret)
    return vals, jnp.take(label_ids, idx)


def bsr_predict_gather_int8_topk(x: jax.Array, model: Int8BlockSparseModel,
                                 sel: jax.Array, k: int, *,
                                 n_labels: int | None = None,
                                 max_per_row: int | None = None,
                                 interpret: bool = True,
                                 ) -> tuple[jax.Array, jax.Array]:
    """Fused gathered int8 predict -> top-k: the shortlist backend's fine
    stage over the quantized artifact. Same contract as
    `bsr_predict_gather_topk` (idx in true label ids, padding masked, sorted
    full-coverage `sel` reproduces `bsr_predict_int8_topk` bit-for-bit —
    the scale multiplies the same per-block fp32 dot in the same order)."""
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    bl = model.block_shape[0]
    sel = jnp.asarray(sel, jnp.int32)
    scores = bsr_predict_gather_int8(x, model, sel, max_per_row=max_per_row,
                                     interpret=interpret)
    label_ids = (sel[:, None] * bl + jnp.arange(bl)[None, :]).reshape(-1)
    if n_labels is not None:
        scores = jnp.where(label_ids[None, :] < n_labels, scores, NEG_INF)
    vals, idx = topk_ops.topk(scores, k, interpret=interpret)
    return vals, jnp.take(label_ids, idx)


def bsr_predict_gather_pq(x: jax.Array, model: BlockSparseModel,
                          sel: jax.Array, *,
                          max_per_row: int | None = None,
                          interpret: bool = True) -> jax.Array:
    """Per-query gathered scores: row q scores ONLY its blocks `sel[q]`.

    sel (n, B) int32 (each row sorted, no duplicates) -> (n, B * bl): row
    q's columns [i*bl, (i+1)*bl) are row block sel[q, i]'s label scores —
    a per-row ragged layout; the topk wrapper owns the per-row label
    translation. Same pad/zero-init conventions as `bsr_predict_gather`.
    """
    x = _pad_features(x, model)
    if max_per_row is None:
        max_per_row = max_blocks_per_row(model)
    return bsr_predict_gather_pq_pallas(
        x, model.blocks, model.block_cols, model.row_ptr,
        jnp.asarray(sel, jnp.int32), max_per_row, interpret=interpret)


def bsr_predict_gather_pq_int8(x: jax.Array, model: Int8BlockSparseModel,
                               sel: jax.Array, *,
                               max_per_row: int | None = None,
                               interpret: bool = True) -> jax.Array:
    """Per-query gathered int8 scores — `bsr_predict_gather_pq` over the
    quantized artifact."""
    x = _pad_features(x, model)
    if max_per_row is None:
        max_per_row = max_blocks_per_row(model)
    return bsr_predict_gather_pq_int8_pallas(
        x, model.blocks, model.scales, model.block_cols, model.row_ptr,
        jnp.asarray(sel, jnp.int32), max_per_row, interpret=interpret)


def _pq_translate_topk(scores: jax.Array, sel: jax.Array, bl: int, k: int,
                       n_labels: int | None, interpret: bool,
                       ) -> tuple[jax.Array, jax.Array]:
    """Shared tail of the per-query topk wrappers: mask block padding per
    row and translate merged top-k back to true label ids via each row's
    own candidate list."""
    from repro.kernels.topk import ops as topk_ops   # deferred: no cycle

    # (n, B*bl): row q's candidate column c is label sel[q, c//bl]*bl + c%bl.
    label_ids = (sel[:, :, None] * bl
                 + jnp.arange(bl)[None, None, :]).reshape(sel.shape[0], -1)
    if n_labels is not None:
        scores = jnp.where(label_ids < n_labels, scores, NEG_INF)
    vals, idx = topk_ops.topk(scores, k, interpret=interpret)
    return vals, jnp.take_along_axis(label_ids, idx, axis=1)


def bsr_predict_gather_pq_topk(x: jax.Array, model: BlockSparseModel,
                               sel: jax.Array, k: int, *,
                               n_labels: int | None = None,
                               max_per_row: int | None = None,
                               interpret: bool = True,
                               ) -> tuple[jax.Array, jax.Array]:
    """Fused per-query gathered predict -> top-k over each row's own
    shortlist. (vals, idx) each (n, k); idx in TRUE label ids (row q's
    candidates translated through sel[q]). Padding labels are masked to
    -inf between the kernels, same as every other topk wrapper here."""
    bl = model.block_shape[0]
    sel = jnp.asarray(sel, jnp.int32)
    scores = bsr_predict_gather_pq(x, model, sel, max_per_row=max_per_row,
                                   interpret=interpret)
    return _pq_translate_topk(scores, sel, bl, k, n_labels, interpret)


def bsr_predict_gather_pq_int8_topk(x: jax.Array,
                                    model: Int8BlockSparseModel,
                                    sel: jax.Array, k: int, *,
                                    n_labels: int | None = None,
                                    max_per_row: int | None = None,
                                    interpret: bool = True,
                                    ) -> tuple[jax.Array, jax.Array]:
    """Fused per-query gathered int8 predict -> top-k: same contract as
    `bsr_predict_gather_pq_topk` over the quantized artifact."""
    bl = model.block_shape[0]
    sel = jnp.asarray(sel, jnp.int32)
    scores = bsr_predict_gather_pq_int8(x, model, sel,
                                        max_per_row=max_per_row,
                                        interpret=interpret)
    return _pq_translate_topk(scores, sel, bl, k, n_labels, interpret)


def gather_flops(model: BlockSparseModel, n: int, sel: np.ndarray) -> int:
    """FLOPs the gathered fine stage actually executes for one batch:
    2 * n * bl * bd per surviving block of the selected row blocks."""
    bl, bd = model.block_shape
    ptr = np.asarray(model.row_ptr)
    sel = np.asarray(sel)
    n_sel_blocks = int((ptr[sel + 1] - ptr[sel]).sum())
    return 2 * n * bl * bd * n_sel_blocks


def gather_pq_flops(model: BlockSparseModel, sel: np.ndarray) -> int:
    """FLOPs of the per-query fine stage: 2 * bl * bd per surviving block
    of each ROW's selected row blocks — each query pays only for its own
    list (sel is (n, B)), which is the whole point of the ragged kernel."""
    bl, bd = model.block_shape
    ptr = np.asarray(model.row_ptr)
    sel = np.asarray(sel)
    n_sel_blocks = int((ptr[sel + 1] - ptr[sel]).sum())
    return 2 * bl * bd * n_sel_blocks


def model_flops(model: BlockSparseModel, n: int) -> int:
    """FLOPs actually executed: 2 * n * bl * bd per surviving block —
    the block-density speedup the kernel realizes over dense predict."""
    bl, bd = model.block_shape
    return 2 * n * bl * bd * model.n_blocks


def dense_flops(model: BlockSparseModel, n: int) -> int:
    Lp, Dp = model.shape
    return 2 * n * Lp * Dp


def predict_bytes(model: BlockSparseModel, n: int) -> int:
    """Bytes the exhaustive fp32 predict must move through HBM: every
    packed block once, plus x streamed per row block, plus the output."""
    bl, bd = model.block_shape
    Lp, Dp = model.shape
    weights = 4 * model.n_blocks * bl * bd
    x_bytes = 4 * n * Dp * (Lp // bl)        # x re-read per row block
    out = 4 * n * Lp
    return weights + x_bytes + out


def predict_bytes_int8(model, n: int) -> int:
    """Same traffic model for the int8 artifact: 1-byte blocks + 4-byte
    per-block scales; x and the fp32 output are unchanged."""
    bl, bd = model.block_shape
    Lp, Dp = model.shape
    weights = model.n_blocks * bl * bd + 4 * model.n_blocks
    x_bytes = 4 * n * Dp * (Lp // bl)
    out = 4 * n * Lp
    return weights + x_bytes + out
