"""Block-sparse (BSR) prediction kernel — scores = x @ W_pruned^T.

The paper's Delta-pruning (§2.2) leaves W with >= 95% exact zeros. On CPU the
paper stores per-label sparse vectors; the TPU-native equivalent (DESIGN.md
§2) is *block* sparsity: W is tiled into MXU-aligned (bl, bd) blocks, all-zero
blocks are dropped at model-conversion time (core/pruning.to_block_sparse),
and this kernel iterates ONLY over surviving blocks — compute and HBM traffic
scale with block density, not with L x D.

Mechanics: one grid step per packed nonzero block, ordered row-major. The
block's (row, col) coordinates are scalar-prefetched so BlockSpec index_maps
can steer both the x-tile fetch (col) and the output-tile revisit (row).
Because blocks of one label-row are adjacent in the packing, the output tile
(n, bl) stays resident in VMEM for the whole row and is written back once.

VMEM (f32): x tile n*bd + W block bl*bd + out tile n*bl; for n = 256,
bl = bd = 128 that is 128 KB + 64 KB + 128 KB — far under budget, so wide
request batches are fine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128)


def _bsr_kernel(rows_ref, cols_ref, x_ref, blk_ref, o_ref):
    """Grid step k: o[:, rows[k]] += x[:, cols[k]] @ blocks[k]^T."""
    del cols_ref
    k = pl.program_id(0)
    is_new_row = jnp.logical_or(
        k == 0, rows_ref[k] != rows_ref[jnp.maximum(k - 1, 0)])

    @pl.when(is_new_row)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_pallas(x: jax.Array, blocks: jax.Array, block_rows: jax.Array,
                       block_cols: jax.Array, n_row_blocks: int,
                       *, interpret: bool = True) -> jax.Array:
    """x (n, Dp), blocks (nb, bl, bd) row-major packed -> scores (n, Lp).

    Row-blocks with no surviving blocks are never visited; ops.py masks them.
    """
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[pl.BlockSpec((n, bd), lambda k, rows, cols: (0, cols[k])),
                  pl.BlockSpec((1, bl, bd), lambda k, rows, cols: (k, 0, 0))],
        out_specs=pl.BlockSpec((n, bl), lambda k, rows, cols: (0, rows[k])),
    )
    return pl.pallas_call(
        _bsr_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, n_row_blocks * bl), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, x, blocks)


def _bsr_int8_kernel(rows_ref, cols_ref, scales_ref, x_ref, blk_ref, o_ref):
    """Int8 variant of `_bsr_kernel`: the packed block arrives as int8,
    is widened to fp32 in-register, and the per-block scale is applied to
    the fp32 partial product — one scalar multiply per output tile instead
    of bl*bd dequant multiplies, with identical accumulation order to the
    gathered int8 kernel (the bit-for-bit full-coverage contract)."""
    del cols_ref
    k = pl.program_id(0)
    is_new_row = jnp.logical_or(
        k == 0, rows_ref[k] != rows_ref[jnp.maximum(k - 1, 0)])

    @pl.when(is_new_row)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += scales_ref[k] * jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_int8_pallas(x: jax.Array, blocks: jax.Array,
                            scales: jax.Array, block_rows: jax.Array,
                            block_cols: jax.Array, n_row_blocks: int,
                            *, interpret: bool = True) -> jax.Array:
    """x (n, Dp), blocks (nb, bl, bd) int8 row-major packed, scales (nb,)
    fp32 -> scores (n, Lp) fp32. HBM traffic for the model payload is
    nb*bl*bd bytes + 4*nb scale bytes — ~0.25x the fp32 kernel's.

    The scales ride in scalar memory next to the block coordinates (both
    are scalar-prefetched), so each grid step reads one f32 alongside its
    int8 tile. Row-blocks with no surviving blocks are never visited;
    ops.py masks them, exactly like the fp32 path.
    """
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(nb,),
        in_specs=[pl.BlockSpec((n, bd),
                               lambda k, rows, cols, scales: (0, cols[k])),
                  pl.BlockSpec((1, bl, bd),
                               lambda k, rows, cols, scales: (k, 0, 0))],
        out_specs=pl.BlockSpec((n, bl),
                               lambda k, rows, cols, scales: (0, rows[k])),
    )
    return pl.pallas_call(
        _bsr_int8_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, n_row_blocks * bl), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, scales, x, blocks)


def _bsr_gather_kernel(sel_ref, rptr_ref, cols_ref, x_ref, blk_ref, o_ref):
    """Grid step (i, j): j-th packed block of selected row block sel[i].

    o[:, i-th tile] += x[:, cols[ptr]] @ blocks[ptr]^T  for
    ptr = row_ptr[sel[i]] + j, gated on j < blocks-in-row — padding steps
    (rows shorter than the grid's max) fetch a clamped tile and add nothing.
    The output tile is zero-initialized at j == 0 unconditionally, so a
    selected row block with NO surviving blocks yields exact-zero scores —
    the same pruned-label convention as the exhaustive path.
    """
    del cols_ref
    i, j = pl.program_id(0), pl.program_id(1)
    r = sel_ref[i]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(rptr_ref[r] + j < rptr_ref[r + 1])
    def _acc():
        o_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_gather_pallas(x: jax.Array, blocks: jax.Array,
                              block_cols: jax.Array, row_ptr: jax.Array,
                              sel: jax.Array, max_blocks_per_row: int,
                              *, interpret: bool = True) -> jax.Array:
    """Gathered-block BSR predict: score only the row blocks listed in `sel`.

    x (n, Dp), blocks (nb, bl, bd) row-major packed, row_ptr (R + 1,),
    sel (B,) int32 row-block ids (any order, no duplicates) -> scores
    (n, B * bl), where columns [i*bl, (i+1)*bl) are the scores of row block
    sel[i]'s labels. `max_blocks_per_row` bounds the inner grid dimension
    (static: max(row_ptr[r+1] - row_ptr[r]) over all row blocks, >= 1).

    Both BlockSpec index maps clamp the packed pointer to nb - 1 so padding
    grid steps (j beyond a short row's block count) fetch a valid tile; the
    kernel body gates their accumulation off. Compute and HBM traffic scale
    with the selected blocks, not with L.
    """
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    B = sel.shape[0]

    def _ptr(i, j, sel_a, rptr_a, cols_a):
        return jnp.minimum(rptr_a[sel_a[i]] + j, nb - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, max_blocks_per_row),
        in_specs=[
            pl.BlockSpec((n, bd),
                         lambda i, j, sel_a, rptr_a, cols_a:
                         (0, cols_a[_ptr(i, j, sel_a, rptr_a, cols_a)])),
            pl.BlockSpec((1, bl, bd),
                         lambda i, j, sel_a, rptr_a, cols_a:
                         (_ptr(i, j, sel_a, rptr_a, cols_a), 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, bl),
                               lambda i, j, sel_a, rptr_a, cols_a: (0, i)),
    )
    return pl.pallas_call(
        _bsr_gather_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, B * bl), jnp.float32),
        interpret=interpret,
    )(sel, row_ptr, block_cols, x, blocks)


def _bsr_gather_int8_kernel(sel_ref, rptr_ref, cols_ref, scales_ref,
                            x_ref, blk_ref, o_ref):
    """Int8 variant of `_bsr_gather_kernel`: same clamp/gate structure,
    with the clamped packed pointer also indexing the per-block scale and
    the scale applied to the fp32 partial product — the same in-register
    dequantization as the exhaustive int8 kernel, so full coverage is
    bit-for-bit identical."""
    del cols_ref
    i, j = pl.program_id(0), pl.program_id(1)
    r = sel_ref[i]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(rptr_ref[r] + j < rptr_ref[r + 1])
    def _acc():
        ptr = rptr_ref[r] + j            # in-bounds inside the gate
        o_ref[...] += scales_ref[ptr] * jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_gather_int8_pallas(x: jax.Array, blocks: jax.Array,
                                   scales: jax.Array, block_cols: jax.Array,
                                   row_ptr: jax.Array, sel: jax.Array,
                                   max_blocks_per_row: int,
                                   *, interpret: bool = True) -> jax.Array:
    """Gathered-block int8 predict: the shortlist fine stage over int8
    tiles. Same contract as `bsr_predict_gather_pallas` with (blocks int8,
    scales fp32) replacing the fp32 blocks; padding grid steps fetch a
    clamped tile and add nothing, and the scale is read only inside the
    in-bounds gate."""
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    B = sel.shape[0]

    def _ptr(i, j, sel_a, rptr_a, cols_a, scales_a):
        return jnp.minimum(rptr_a[sel_a[i]] + j, nb - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, max_blocks_per_row),
        in_specs=[
            pl.BlockSpec((n, bd),
                         lambda i, j, sel_a, rptr_a, cols_a, scales_a:
                         (0, cols_a[_ptr(i, j, sel_a, rptr_a, cols_a,
                                         scales_a)])),
            pl.BlockSpec((1, bl, bd),
                         lambda i, j, sel_a, rptr_a, cols_a, scales_a:
                         (_ptr(i, j, sel_a, rptr_a, cols_a, scales_a),
                          0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (n, bl),
            lambda i, j, sel_a, rptr_a, cols_a, scales_a: (0, i)),
    )
    return pl.pallas_call(
        _bsr_gather_int8_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, B * bl), jnp.float32),
        interpret=interpret,
    )(sel, row_ptr, block_cols, scales, x, blocks)


def _bsr_gather_pq_kernel(sel_ref, rptr_ref, cols_ref, x_ref, blk_ref, o_ref):
    """Ragged per-query gather, grid step (q, i, j): j-th packed block of
    row block sel[q, i] — query q's OWN i-th selected block, scored against
    query q's single row.

    o[q-th row, i-th tile] += x[q, cols[ptr]] @ blocks[ptr]^T  for
    ptr = row_ptr[sel[q, i]] + j, gated on j < blocks-in-row exactly like
    the shared-selection kernel; the (1, bl) output tile is zero-initialized
    at j == 0. Each query walks its own block list, so a query whose
    selection hits sparse row blocks does strictly less accumulation work
    than one that hit dense rows — the shared-B union's worst-case cost is
    gone.
    """
    del cols_ref
    q, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    r = sel_ref[q, i]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(rptr_ref[r] + j < rptr_ref[r + 1])
    def _acc():
        o_ref[...] += jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_gather_pq_pallas(x: jax.Array, blocks: jax.Array,
                                 block_cols: jax.Array, row_ptr: jax.Array,
                                 sel: jax.Array, max_blocks_per_row: int,
                                 *, interpret: bool = True) -> jax.Array:
    """Per-query gathered-block BSR predict: row q scores only ITS row
    blocks `sel[q]`.

    x (n, Dp), blocks (nb, bl, bd) row-major packed, row_ptr (R + 1,),
    sel (n, B) int32 — row q's B selected row-block ids (sorted, no
    duplicates) -> scores (n, B * bl), where row q's columns
    [i*bl, (i+1)*bl) are the scores of row block sel[q, i]'s labels (a
    per-row ragged layout; ops.py owns the per-row label translation).

    The grid is (n, B, max_blocks_per_row) with j innermost, so each
    (1, bl) output tile stays resident across its row block's packed
    blocks. Both index maps clamp the packed pointer to nb - 1 so padding
    steps fetch a valid tile; the body gates their accumulation off.

    Numerics note: the per-query dot is (1, bd) @ (bd, bl) — NOT bitwise
    identical to one row of the shared kernel's (n, bd) @ (bd, bl) dot on
    every backend, which is why `ShortlistBackend` collapses B == R (where
    every per-query list provably equals the full sorted block list) to
    the shared kernel: the full-width bit-exactness contract rides on the
    proven path, and this kernel serves only genuinely ragged B < R work.
    At n == 1 the shapes coincide and the two kernels ARE bit-identical
    (tested).
    """
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    B = sel.shape[1]

    def _ptr(q, i, j, sel_a, rptr_a, cols_a):
        return jnp.minimum(rptr_a[sel_a[q, i]] + j, nb - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n, B, max_blocks_per_row),
        in_specs=[
            pl.BlockSpec((1, bd),
                         lambda q, i, j, sel_a, rptr_a, cols_a:
                         (q, cols_a[_ptr(q, i, j, sel_a, rptr_a, cols_a)])),
            pl.BlockSpec((1, bl, bd),
                         lambda q, i, j, sel_a, rptr_a, cols_a:
                         (_ptr(q, i, j, sel_a, rptr_a, cols_a), 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bl), lambda q, i, j, sel_a, rptr_a, cols_a: (q, i)),
    )
    return pl.pallas_call(
        _bsr_gather_pq_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, B * bl), jnp.float32),
        interpret=interpret,
    )(sel, row_ptr, block_cols, x, blocks)


def _bsr_gather_pq_int8_kernel(sel_ref, rptr_ref, cols_ref, scales_ref,
                               x_ref, blk_ref, o_ref):
    """Int8 variant of `_bsr_gather_pq_kernel`: identical clamp/gate
    structure, with the in-bounds packed pointer indexing the per-block
    scale and the scale applied to the fp32 partial product — the same
    in-register dequantization as every other int8 kernel in this file."""
    del cols_ref
    q, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    r = sel_ref[q, i]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(rptr_ref[r] + j < rptr_ref[r + 1])
    def _acc():
        ptr = rptr_ref[r] + j            # in-bounds inside the gate
        o_ref[...] += scales_ref[ptr] * jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_gather_pq_int8_pallas(x: jax.Array, blocks: jax.Array,
                                      scales: jax.Array,
                                      block_cols: jax.Array,
                                      row_ptr: jax.Array, sel: jax.Array,
                                      max_blocks_per_row: int,
                                      *, interpret: bool = True) -> jax.Array:
    """Per-query gathered-block int8 predict: same contract as
    `bsr_predict_gather_pq_pallas` with (blocks int8, scales fp32)
    replacing the fp32 blocks."""
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    B = sel.shape[1]

    def _ptr(q, i, j, sel_a, rptr_a, cols_a, scales_a):
        return jnp.minimum(rptr_a[sel_a[q, i]] + j, nb - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(n, B, max_blocks_per_row),
        in_specs=[
            pl.BlockSpec((1, bd),
                         lambda q, i, j, sel_a, rptr_a, cols_a, scales_a:
                         (q, cols_a[_ptr(q, i, j, sel_a, rptr_a, cols_a,
                                         scales_a)])),
            pl.BlockSpec((1, bl, bd),
                         lambda q, i, j, sel_a, rptr_a, cols_a, scales_a:
                         (_ptr(q, i, j, sel_a, rptr_a, cols_a, scales_a),
                          0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bl),
            lambda q, i, j, sel_a, rptr_a, cols_a, scales_a: (q, i)),
    )
    return pl.pallas_call(
        _bsr_gather_pq_int8_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, B * bl), jnp.float32),
        interpret=interpret,
    )(sel, row_ptr, block_cols, scales, x, blocks)
