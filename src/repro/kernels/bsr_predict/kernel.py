"""Block-sparse (BSR) prediction kernel — scores = x @ W_pruned^T.

The paper's Delta-pruning (§2.2) leaves W with >= 95% exact zeros. On CPU the
paper stores per-label sparse vectors; the TPU-native equivalent (DESIGN.md
§2) is *block* sparsity: W is tiled into MXU-aligned (bl, bd) blocks, all-zero
blocks are dropped at model-conversion time (core/pruning.to_block_sparse),
and this kernel iterates ONLY over surviving blocks — compute and HBM traffic
scale with block density, not with L x D.

Mechanics: one grid step per packed nonzero block, ordered row-major. The
block's (row, col) coordinates are scalar-prefetched so BlockSpec index_maps
can steer both the x-tile fetch (col) and the output-tile revisit (row).
Because blocks of one label-row are adjacent in the packing, the output tile
(n, bl) stays resident in VMEM for the whole row and is written back once.

VMEM (f32): x tile n*bd + W block bl*bd + out tile n*bl; for n = 256,
bl = bd = 128 that is 128 KB + 64 KB + 128 KB — far under budget, so wide
request batches are fine.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (128, 128)


def _bsr_kernel(rows_ref, cols_ref, x_ref, blk_ref, o_ref):
    """Grid step k: o[:, rows[k]] += x[:, cols[k]] @ blocks[k]^T."""
    del cols_ref
    k = pl.program_id(0)
    is_new_row = jnp.logical_or(
        k == 0, rows_ref[k] != rows_ref[jnp.maximum(k - 1, 0)])

    @pl.when(is_new_row)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), blk_ref[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)


def bsr_predict_pallas(x: jax.Array, blocks: jax.Array, block_rows: jax.Array,
                       block_cols: jax.Array, n_row_blocks: int,
                       *, interpret: bool = True) -> jax.Array:
    """x (n, Dp), blocks (nb, bl, bd) row-major packed -> scores (n, Lp).

    Row-blocks with no surviving blocks are never visited; ops.py masks them.
    """
    n = x.shape[0]
    nb, bl, bd = blocks.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[pl.BlockSpec((n, bd), lambda k, rows, cols: (0, cols[k])),
                  pl.BlockSpec((1, bl, bd), lambda k, rows, cols: (k, 0, 0))],
        out_specs=pl.BlockSpec((n, bl), lambda k, rows, cols: (0, rows[k])),
    )
    return pl.pallas_call(
        _bsr_kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, n_row_blocks * bl), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, x, blocks)
