"""Pure-jnp oracle for BSR prediction: dense matmul against the
densified block-sparse matrix."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pruning import BlockSparseModel


def bsr_predict(x: jax.Array, model: BlockSparseModel) -> jax.Array:
    W = model.to_dense()
    return x.astype(jnp.float32) @ W.T.astype(jnp.float32)
