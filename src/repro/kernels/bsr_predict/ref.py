"""Pure-jnp oracle for BSR prediction: dense matmul against the
densified block-sparse matrix."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pruning import BlockSparseModel, Int8BlockSparseModel


def bsr_predict(x: jax.Array, model: BlockSparseModel) -> jax.Array:
    W = model.to_dense()
    return x.astype(jnp.float32) @ W.T.astype(jnp.float32)


def bsr_predict_int8(x: jax.Array, model: Int8BlockSparseModel) -> jax.Array:
    """Oracle for the int8 kernel: dequantize every block to fp32 (the
    exact values the kernel reconstructs in-register) then dense matmul."""
    return bsr_predict(x, model.dequantize())


def bsr_predict_gather_int8(x: jax.Array, model: Int8BlockSparseModel,
                            sel: jax.Array) -> jax.Array:
    return bsr_predict_gather(x, model.dequantize(), sel)


def bsr_predict_gather(x: jax.Array, model: BlockSparseModel,
                       sel: jax.Array) -> jax.Array:
    """Oracle for the gathered-block kernel: dense scores against the
    densified model, with the selected row blocks' label columns gathered
    into (n, B * bl) in `sel` order."""
    bl = model.block_shape[0]
    scores = bsr_predict(x, model)                       # (n, Lp)
    cols = (jnp.asarray(sel, jnp.int32)[:, None] * bl
            + jnp.arange(bl)[None, :]).reshape(-1)       # (B * bl,)
    return scores[:, cols]
