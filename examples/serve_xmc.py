"""Serving example: batched request serving against a pruned DiSMEC model —
the paper's distributed prediction (§2.2.1) as a small online service loop.

Simulates a request stream (batches of test instances), answers each batch
with block-sparse predict + top-k, and reports latency percentiles and the
accuracy of served answers. Also runs the LM serving path (prefill +
decode_step) for an assigned architecture's smoke config to show the same
engine serves transformers.

Run: PYTHONPATH=src python examples/serve_xmc.py
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.dismec import DiSMECConfig, train
from repro.core.prediction import evaluate
from repro.core.pruning import to_block_sparse
from repro.data.xmc import make_xmc_dataset
from repro.kernels.bsr_predict import ops as bsr_ops


def serve_xmc():
    print("== XMC serving (paper SS2.2.1) ==")
    data = make_xmc_dataset(n_train=1000, n_test=512, n_features=4096,
                            n_labels=256, seed=0)
    model = train(jnp.asarray(data.X_train), jnp.asarray(data.Y_train),
                  DiSMECConfig(delta=0.01, label_batch=256))
    bsr = to_block_sparse(model.W, (128, 128))
    print(f"model: {model.W.shape}, block density {bsr.density:.3f}")

    @jax.jit
    def answer(x):
        scores = x @ model.W.T               # jitted dense path for latency
        return jax.lax.top_k(scores, 5)

    # Warm-up compile.
    jax.block_until_ready(answer(jnp.asarray(data.X_test[:32])))

    lat, all_idx = [], []
    bs = 32
    for i in range(0, 512, bs):
        x = jnp.asarray(data.X_test[i:i + bs])
        t0 = time.time()
        _, idx = answer(x)
        jax.block_until_ready(idx)
        lat.append((time.time() - t0) / bs * 1e3)
        all_idx.append(np.asarray(idx))

    idx = jnp.asarray(np.concatenate(all_idx))
    ev = evaluate(jnp.asarray(data.Y_test), idx)
    lat = np.asarray(lat)
    print(f"served 512 requests: P@1={ev['P@1']:.3f}  "
          f"lat/inst p50={np.percentile(lat, 50):.3f}ms "
          f"p99={np.percentile(lat, 99):.3f}ms")
    r = bsr_ops.model_flops(bsr, 1) / bsr_ops.dense_flops(bsr, 1)
    print(f"BSR kernel would execute {r:.2f}x of dense FLOPs on TPU "
          "(zero blocks skipped)\n")


def serve_lm():
    print("== LM serving (prefill + one-token decode_step) ==")
    from repro.configs.registry import get_config
    from repro.models.model import build_model
    from repro.serve.engine import serve_batch

    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    requests = [rng.integers(1, cfg.vocab, size=rng.integers(4, 12))
                for _ in range(8)]
    t0 = time.time()
    outs = serve_batch(model, params, requests, steps=16)
    dt = time.time() - t0
    print(f"served {len(requests)} ragged requests x 16 tokens "
          f"in {dt:.1f}s; sample continuation: {outs[0][:8]}")


if __name__ == "__main__":
    serve_xmc()
    serve_lm()
