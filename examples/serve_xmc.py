"""Serving example: a thin client of the spec-driven serving session.

Streams a small DiSMEC model into the sparse multi-shard checkpoint (the
paper's offline model files, written by the label-batch training pipeline),
re-opens it as a `CheckpointHandle` (the spec rides in the manifest), then
serves the same ragged request stream through each registered predict
backend by overriding just the handle's `ServeSpec` — dense / BSR-Pallas /
mesh-sharded share one set of weights — and reports latency percentiles,
accuracy of served answers, and cross-backend agreement. Also runs the LM
serving path to show both engines share one subsystem.

Run: PYTHONPATH=src python examples/serve_xmc.py
"""

import tempfile
import time

import numpy as np

import jax.numpy as jnp

from repro.core.prediction import evaluate
from repro.kernels.bsr_predict import ops as bsr_ops
from repro.serve import BACKENDS
from repro.specs import ServeSpec
from repro.train.xmc import train_demo_checkpoint
from repro.xmc_api import CheckpointHandle


def serve_xmc():
    print("== XMC serving (paper SS2.2.1) ==")
    # The paper's offline model files: streamed sparse once (shared demo
    # pipeline, also behind launch/serve.py --xmc), served many times.
    with tempfile.TemporaryDirectory() as ckpt:
        data, _ = train_demo_checkpoint(ckpt, n_train=1000, n_test=512,
                                        n_features=4096, n_labels=256,
                                        label_batch=128, seed=0)
        handle = CheckpointHandle.open(ckpt)       # spec from manifest alone
        bsr, _ = handle.model()
        print(f"model: {(data.n_labels, data.n_features)}, "
              f"block density {bsr.density:.3f}, "
              f"spec delta={handle.spec.solver.delta}")

        # A ragged request stream over the test pool.
        rng = np.random.default_rng(0)
        X = np.asarray(data.X_test, np.float32)
        requests, truths = [], []
        i = 0
        while i < 512:
            n_i = int(rng.integers(1, 9))
            requests.append(X[i:i + n_i])
            truths.append(np.asarray(data.Y_test[i:i + n_i]))
            i += n_i

        served = {}
        n_rb = int(np.asarray(bsr.row_ptr).shape[0]) - 1
        for kind in BACKENDS:
            # Full-width shortlist (B = all row blocks) is bit-exact vs
            # exhaustive BSR, so it joins the agreement check; the
            # sub-linear B-of-R trade is gated in benchmarks/serve_latency.
            spec = (ServeSpec(backend=kind, k=5, shortlist_blocks=n_rb)
                    if kind == "shortlist" else ServeSpec(backend=kind, k=5))
            engine = handle.engine(spec)
            results = engine.serve(requests)
            stats = engine.latency_summary()
            idx = np.concatenate([r.labels for r in results], axis=0)
            ev = evaluate(jnp.asarray(np.concatenate(truths, axis=0)),
                          jnp.asarray(idx))
            served[kind] = idx
            print(f"  {kind:8s} {len(results)} requests: "
                  f"P@1={ev['P@1']:.3f}  p50={stats['p50_ms']:.3f}ms "
                  f"p99={stats['p99_ms']:.3f}ms")

    agree = all((served[k] == served["dense"]).all() for k in BACKENDS)
    print(f"backends agree on every top-5 label: {agree}")
    r = bsr_ops.model_flops(bsr, 1) / bsr_ops.dense_flops(bsr, 1)
    print(f"BSR kernel executes {r:.2f}x of dense FLOPs on TPU "
          "(zero blocks skipped)\n")


def serve_lm():
    print("== LM serving (prefill + one-token decode_step) ==")
    import jax
    from repro.configs.registry import get_config
    from repro.models.model import build_model
    from repro.serve import serve_batch

    cfg = get_config("qwen1.5-0.5b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    requests = [rng.integers(1, cfg.vocab, size=rng.integers(4, 12))
                for _ in range(8)]
    t0 = time.time()
    outs = serve_batch(model, params, requests, steps=16)
    dt = time.time() - t0
    print(f"served {len(requests)} ragged requests x 16 tokens "
          f"in {dt:.1f}s; sample continuation: {outs[0][:8]}")


if __name__ == "__main__":
    serve_xmc()
    serve_lm()
