"""Distributed training example: the paper's double parallelization on a
JAX mesh (8 simulated devices on CPU; the same code drives the 256-chip
production mesh in launch/).

Layer 1 (paper: label batches -> nodes)  = label axis sharded over `model`.
Layer 2 (paper: one label per core)      = batched TRON per shard.
Beyond paper: instances sharded over `data` with psum'd gradients/Hv.

NOTE: the 8-device XLA flag is set before importing jax — run this script
directly, not from a process that already initialized jax.

Run: PYTHONPATH=src python examples/distributed_dismec.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp

from repro.core.dismec import DiSMECConfig, train, train_sharded
from repro.core.prediction import evaluate, predict_topk_sharded
from repro.data.xmc import make_xmc_dataset


def main():
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    data = make_xmc_dataset(n_train=1024, n_test=256, n_features=2048,
                            n_labels=256, seed=0)
    X, Y = jnp.asarray(data.X_train), jnp.asarray(data.Y_train)
    cfg = DiSMECConfig(C=1.0, delta=0.01, label_batch=256)

    # Paper-faithful: X replicated per label-shard "node" (SS2.1).
    t0 = time.time()
    m_paper = train_sharded(X, Y, cfg, mesh)
    t_paper = time.time() - t0

    # Beyond-paper: X sharded over `data`, grad/Hv reconstituted by psum.
    t0 = time.time()
    m_psum = train_sharded(X, Y, cfg, mesh, shard_data=True)
    t_psum = time.time() - t0

    # Reference: single-device Algorithm 1.
    t0 = time.time()
    m_single = train(X, Y, cfg)
    t_single = time.time() - t0

    err = float(jnp.max(jnp.abs(m_paper.W - m_single.W)))
    err2 = float(jnp.max(jnp.abs(m_psum.W - m_single.W)))
    print(f"single-device: {t_single:.1f}s | label-sharded: {t_paper:.1f}s "
          f"(max|dW|={err:.2e}) | +data-sharded: {t_psum:.1f}s "
          f"(max|dW|={err2:.2e})")

    # Distributed prediction: shard-local top-k + global candidate merge.
    Xte, Yte = jnp.asarray(data.X_test), jnp.asarray(data.Y_test)
    _, idx = predict_topk_sharded(Xte, m_paper.W, 5, mesh)
    print("sharded-predict metrics:", evaluate(Yte, idx))


if __name__ == "__main__":
    main()
