"""Distributed training example: the paper's double parallelization, for
real this time.

Layer 1 (paper: label batches -> nodes)  = N independent worker PROCESSES
    cooperatively draining one label-batch queue through the checkpoint
    manifest's lease table. Each worker runs the same `fit(X, Y, spec,
    out_dir, worker=...)`; batches are claimed atomically, a worker killed
    mid-batch is recovered by lease expiry, and the finished checkpoint is
    bit-identical to a single-worker run. On a cluster you'd launch the
    same thing with plain process spawning on each node
    (`python -m repro.launch.train --xmc --worker-id $HOSTNAME ...`)
    against a shared filesystem — nothing here is multiprocessing-specific.

Layer 2 (paper: one label per core)      = the batched TRON solve inside
    each worker; add `ScheduleSpec(mesh=(d, m))` to also shard every
    batch's solve over an in-process device mesh (see docs/architecture.md
    — the two layers compose).

Run: PYTHONPATH=src python examples/distributed_dismec.py
"""

import json
import multiprocessing as mp
import os
import tempfile
import time

N_WORKERS = 2
DATA = dict(n_train=512, n_test=128, n_features=2048, n_labels=192, seed=0)
LABEL_BATCH = 32                       # 6 batches -> a real queue to deal
BLOCK = (32, 128)


def build_spec():
    from repro.specs import ScheduleSpec, SolverSpec
    from repro.xmc_api import XMCSpec

    # Every worker must build the SAME canonical spec — the manifest
    # fingerprint rejects a joiner whose spec (or data) disagrees.
    return XMCSpec(
        solver=SolverSpec(C=1.0, delta=0.01, eps=1e-2),
        schedule=ScheduleSpec(label_batch=LABEL_BATCH, block_shape=BLOCK,
                              workers=N_WORKERS, lease_ttl=60.0))


def worker_main(worker_id: str, out_dir: str, queue) -> None:
    """One layer-1 node: same data, same spec, shared out_dir."""
    import jax.numpy as jnp

    from repro.data.xmc import make_xmc_dataset
    from repro.xmc_api import fit

    data = make_xmc_dataset(**DATA)              # deterministic per seed
    t0 = time.time()
    handle = fit(jnp.asarray(data.X_train), jnp.asarray(data.Y_train),
                 build_spec(), out_dir, worker=worker_id)
    res = handle.result
    queue.put({"worker": worker_id, "solved": res.solved,
               "complete": res.complete, "wall_s": time.time() - t0})


def main():
    import numpy as np
    import jax.numpy as jnp

    from repro.checkpoint.io import BSR_MANIFEST, load_block_sparse
    from repro.core.prediction import evaluate
    from repro.data.xmc import make_xmc_dataset
    from repro.xmc_api import CheckpointHandle, fit

    ctx = mp.get_context("spawn")                # fresh jax per worker
    with tempfile.TemporaryDirectory() as root:
        coop = os.path.join(root, "coop")

        print(f"layer 1: {N_WORKERS} worker processes draining "
              f"{DATA['n_labels'] // LABEL_BATCH} label batches -> {coop}")
        q = ctx.Queue()
        procs = [ctx.Process(target=worker_main, args=(f"node{i}", coop, q))
                 for i in range(N_WORKERS)]
        t0 = time.time()
        for p in procs:
            p.start()
        # Collect with a timeout + liveness check: a worker that dies
        # before reporting must fail the demo, not hang it on q.get() —
        # and on failure the survivors are terminated first, so the demo
        # exits promptly instead of blocking on multiprocessing's atexit
        # join while tempdir cleanup races their in-flight writes.
        import queue as queue_mod
        reports, deadline = [], time.time() + 600.0
        try:
            while len(reports) < len(procs):
                try:
                    reports.append(q.get(timeout=5.0))
                except queue_mod.Empty:
                    dead = [p for p in procs
                            if not p.is_alive()
                            and p.exitcode not in (0, None)]
                    if dead:
                        raise RuntimeError(
                            f"worker(s) died with exit codes "
                            f"{[p.exitcode for p in dead]}")
                    if time.time() > deadline:
                        raise RuntimeError("timed out waiting for workers")
        except BaseException:
            for p in procs:
                if p.is_alive():
                    p.terminate()
            for p in procs:
                p.join()
            raise
        for p in procs:
            p.join()
        wall = time.time() - t0
        for r in sorted(reports, key=lambda r: r["worker"]):
            print(f"  {r['worker']}: solved batches {r['solved']} "
                  f"in {r['wall_s']:.1f}s (complete={r['complete']})")
        assert any(r["complete"] for r in reports)

        # The cooperative checkpoint must be bit-identical to one worker
        # doing everything alone.
        data = make_xmc_dataset(**DATA)
        single = os.path.join(root, "single")
        fit(jnp.asarray(data.X_train), jnp.asarray(data.Y_train),
            build_spec(), single)
        with open(os.path.join(coop, BSR_MANIFEST)) as f:
            m_coop = json.load(f)
        with open(os.path.join(single, BSR_MANIFEST)) as f:
            m_single = json.load(f)
        assert m_coop == m_single
        np.testing.assert_array_equal(
            np.asarray(load_block_sparse(coop)[0].to_dense()),
            np.asarray(load_block_sparse(single)[0].to_dense()))
        print(f"cooperative checkpoint bit-identical to single-worker run "
              f"({wall:.1f}s wall incl. process spawn)")

        # Serve the cooperative checkpoint: the manifest alone carries the
        # spec, so any process can re-open and serve it.
        engine = CheckpointHandle.open(coop).engine()
        results = engine.serve([np.asarray(data.X_test, np.float32)])
        print("served metrics:", evaluate(jnp.asarray(data.Y_test),
                                          jnp.asarray(results[0].labels)))


if __name__ == "__main__":
    main()
