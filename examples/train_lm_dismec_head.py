"""End-to-end driver (deliverable b): train a ~100M-parameter LM whose
output layer is the paper's technique — a DiSMEC one-vs-rest extreme
classification head — for a few hundred steps.

The arch is the assigned qwen1.5-0.5b family reduced to ~100M params
(the full config is exercised by the multi-pod dry-run; this driver proves
the training loop converges on real hardware — here, CPU).

Run: PYTHONPATH=src python examples/train_lm_dismec_head.py \
        [--steps 300] [--head dismec|softmax]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.lm import make_lm_batch_iterator
from repro.models.model import build_model
from repro.train.trainer import train_loop


def make_100m_config(head_type: str) -> ArchConfig:
    """~100M params: 6L x d512 x ffn 2048, 32k vocab (qwen-style GQA)."""
    return ArchConfig(
        name="qwen-100m", family="dense", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=32768, qkv_bias=True,
        head_type=head_type, dtype="float32",
        source="reduced qwen1.5 family [hf:Qwen/Qwen1.5-0.5B]",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--head", choices=["dismec", "softmax"],
                    default="dismec")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = make_100m_config(args.head)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"head={cfg.head_type} over vocab {cfg.padded_vocab()}")

    batches = make_lm_batch_iterator(cfg.vocab, args.seq, args.batch, seed=0)
    t0 = time.time()
    params, hist = train_loop(model, params, batches, steps=args.steps,
                              lr=3e-4, warmup=20, log_every=20)
    dt = time.time() - t0
    for h in hist:
        print(f"  step {h['step']:4d}  loss {h['loss']:10.4f}  "
              f"lr {h['lr']:.2e}")
    toks = args.steps * args.batch * args.seq
    print(f"\ntrained {args.steps} steps ({toks} tokens) in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s on CPU)")
    first = hist[0]["loss"]
    last = hist[-1]["loss"]
    print(f"loss {first:.2f} -> {last:.2f} "
          f"({'DECREASED OK' if last < first else 'NOT DECREASED'})")


if __name__ == "__main__":
    main()
