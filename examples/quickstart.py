"""Quickstart: the paper end-to-end through the declarative session API.

One frozen `XMCSpec` describes the whole experiment — solver (Algorithm 1's
hyper-parameters), schedule (label-batch streaming), and serving plan —
and three calls run it:

  fit(X, Y, spec, ckpt)            train -> streamed sparse checkpoint
  CheckpointHandle.open(ckpt)      re-open it, spec recovered from the
                                   manifest alone
  handle.engine()                  serve top-k exactly as the spec says

plus the warm-start session: re-fit under a changed spec with
`init_from=` seeding every label batch's TRON from the prior checkpoint.

Run: PYTHONPATH=src python examples/quickstart.py
     PYTHONPATH=src python examples/quickstart.py --smoke   # tiny shapes
                                                  # (the verify.sh docs gate)
"""

import argparse
import tempfile

import numpy as np

import jax.numpy as jnp

from repro.core.prediction import evaluate
from repro.data.xmc import make_xmc_dataset
from repro.specs import ScheduleSpec, ServeSpec, SolverSpec
from repro.xmc_api import CheckpointHandle, XMCSpec, fit

DIMS = dict(n_train=1500, n_test=500, n_features=4096, n_labels=512)
# --smoke (tools/verify.sh): same session end-to-end on tiny shapes.
SMOKE_DIMS = dict(n_train=300, n_test=100, n_features=1024, n_labels=128)


def main(smoke: bool = False):
    dims = SMOKE_DIMS if smoke else DIMS

    # 1. Power-law XMC data (Eq. 1.1: N_r = N_1 r^-beta).
    data = make_xmc_dataset(beta=1.0, seed=0, **dims)
    print("dataset:", data.stats())
    X, Y = jnp.asarray(data.X_train), jnp.asarray(data.Y_train)
    queries = np.asarray(data.X_test, np.float32)

    # 2. The experiment as one JSON-round-trippable value.
    spec = XMCSpec(
        solver=SolverSpec(C=1.0, delta=0.01),          # Eq. 2.2 + step 7
        schedule=ScheduleSpec(label_batch=128),        # layer-1 batches
        serve=ServeSpec(backend="bsr", k=5))           # §2.2.1 serving
    assert XMCSpec.from_json(spec.to_json()) == spec
    print("spec:", spec.to_json())

    with tempfile.TemporaryDirectory() as root:
        ckpt = f"{root}/model"

        # 3. fit: Algorithm 1 streamed into a servable sparse checkpoint
        #    (device memory O(label_batch x D); killed runs resume).
        handle = fit(X, Y, spec, ckpt)
        model, _ = handle.model()
        print(f"model: {model.orig_shape}, block density "
              f"{model.density:.3f} after Delta-pruning")

        # 4. The checkpoint alone reproduces the experiment description.
        reopened = CheckpointHandle.open(ckpt)
        assert reopened.spec == spec

        # 5. Serve as the spec says (paper Table 2 metrics on the answers).
        engine = reopened.engine()
        results = engine.serve([queries])
        print("metrics:", evaluate(jnp.asarray(data.Y_test),
                                   jnp.asarray(results[0].labels)))

        # 6. Same weights, different serving plan: override just ServeSpec.
        dense = reopened.engine(ServeSpec(backend="dense", k=5))
        agree = float((dense.serve([queries])[0].labels
                       == results[0].labels).mean())
        print(f"dense backend top-5 agreement: {agree:.4f}")

        # 7. Warm start: re-train with a sharper capacity control from the
        #    converged weights instead of zeros (the spec delta changes,
        #    the session maps the old shards back to label ranges as W0).
        sharper = spec.replace(solver=spec.solver.replace(delta=0.02))
        handle2 = fit(X, Y, sharper, f"{root}/model-d02", init_from=ckpt)
        model2, _ = handle2.model()
        print(f"warm-started delta=0.02 refit: block density "
              f"{model2.density:.3f} (was {model.density:.3f})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI docs gate)")
    main(smoke=ap.parse_args().smoke)
