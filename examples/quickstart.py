"""Quickstart: the paper end-to-end in ~40 lines of public API.

Generates a power-law XMC dataset (paper Fig. 1 statistics), trains DiSMEC
(Algorithm 1: batched TRON + Delta-pruning), evaluates P@k / nDCG@k
(paper §3.2), and serves through the block-sparse predict kernel (§2.2.1).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.dismec import DiSMECConfig, train
from repro.core.prediction import evaluate, predict_topk
from repro.core.pruning import to_block_sparse
from repro.data.xmc import make_xmc_dataset
from repro.kernels.bsr_predict import ops as bsr_ops


def main():
    # 1. Power-law XMC data (Eq. 1.1: N_r = N_1 r^-beta).
    data = make_xmc_dataset(n_train=1500, n_test=500, n_features=4096,
                            n_labels=512, beta=1.0, seed=0)
    print("dataset:", data.stats())

    # 2. Algorithm 1: one-vs-rest squared-hinge SVMs, batched TRON solver,
    #    Delta=0.01 ambiguity pruning (steps 3-7).
    cfg = DiSMECConfig(C=1.0, delta=0.01, label_batch=512)
    model = train(jnp.asarray(data.X_train), jnp.asarray(data.Y_train), cfg)
    print(f"model: {model.W.shape}, density "
          f"{model.nnz / model.W.size:.3f} after Delta-pruning")

    # 3. Evaluate (paper Table 2 metrics).
    _, topk = predict_topk(jnp.asarray(data.X_test), model.W, 5)
    print("metrics:", evaluate(jnp.asarray(data.Y_test), topk))

    # 4. Serving path (paper §2.2.1): block-sparse model, zero blocks
    #    skipped by the Pallas kernel (interpret mode on CPU).
    bsr = to_block_sparse(model.W, (128, 128))
    scores = bsr_ops.bsr_predict(jnp.asarray(data.X_test), bsr)
    _, topk_bsr = jax.lax.top_k(scores[:, :model.n_labels], 5)
    agree = float((topk == topk_bsr).mean())
    print(f"BSR serving: block density {bsr.density:.3f}, "
          f"executes {bsr_ops.model_flops(bsr, 500) / bsr_ops.dense_flops(bsr, 500):.2f}x dense FLOPs, "
          f"top-k agreement {agree:.4f}")


if __name__ == "__main__":
    main()
